"""LSMR + recycled LSMR: references, kernel parity, front-door dispatch.

Four layers:

  1. reference parity: flat Golub-Kahan LSMR vs dense ``jnp.linalg.lstsq``
     (plain least-squares) and vs the dense normal-equations solve
     (ridge, ``damp > 0``) — including warm starts, which the augmented
     ``[A; √λ I]`` formulation keeps EXACT rather than prox-approximate;
  2. oracle parity of the fused ``lsmr_update`` kernel op (the
     three-vector x/hbar/h recurrence) across the impl contract —
     ``interpret`` and ``chunked`` vs ``ref.lsmr_update``, with
     ``pallas`` compiled and ``reference`` the oracle itself;
  3. recycling wins: on an ill-conditioned drifting sequence, deflated
     warm-started LSMR must beat cold LSMR on iterations AND total
     A/Aᵀ products, at equal accuracy;
  4. front-door dispatch: ``SolveSpec.method ∈ {"lsmr", "deflsmr"}``
     through ``solve`` / ``solve_sequence`` / ``solve_batch`` /
     ``solve_pool_step`` with zero new entry points.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DenseMatrixOperator,
    RecycleState,
    SolveSpec,
    lsmr,
    lsmr_jit,
    solve,
    solve_batch,
    solve_pool_step,
    solve_sequence,
    solve_sequence_lsmr,
)
from repro.core.solvers import SolveStatus
from repro.kernels import ops, ref


def _rect(m, n, seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)))
    b = jnp.asarray(rng.standard_normal(m))
    return A, b


def _ill_conditioned_sequence(num, m=90, n=60, drift=0.02, seed=3):
    """Drifting rectangular systems with logspace(0,-3) singular decay —
    the regime where deflating the slow tail pays (flat spectra tie)."""
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.standard_normal((m, m)))
    V, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.logspace(0, -3, n)
    base = U[:, :n] @ np.diag(s) @ V.T
    mats, bs = [], []
    for _ in range(num):
        mats.append(jnp.asarray(base))
        bs.append(jnp.asarray(rng.standard_normal(m)))
        base = base + drift * np.linalg.norm(base) / np.sqrt(m * n) * (
            rng.standard_normal((m, n))
        )
    return jnp.stack(mats), jnp.stack(bs)


# ---------------------------------------------------------------------------
# 1. dense references
# ---------------------------------------------------------------------------


class TestLSMRReference:
    @pytest.mark.parametrize("m,n", [(80, 50), (50, 80)])
    def test_matches_dense_lstsq(self, m, n):
        A, b = _rect(m, n, seed=m + n)
        res = lsmr(DenseMatrixOperator(A), b, tol=1e-13, maxiter=400)
        x_ref, *_ = jnp.linalg.lstsq(A, b)
        err = float(
            jnp.linalg.norm(res.x - x_ref) / jnp.linalg.norm(x_ref)
        )
        assert bool(res.info.converged)
        assert err < 1e-8
        # honest accounting: init Aᵀ + 2 per iteration
        assert int(res.info.matvecs) == 1 + 2 * int(res.info.iterations)

    def test_matches_dense_ridge(self):
        m, n, lam = 70, 40, 0.25
        A, b = _rect(m, n, seed=11)
        res = lsmr(DenseMatrixOperator(A), b, damp=lam, tol=1e-13,
                   maxiter=400)
        x_ref = jnp.linalg.solve(
            A.T @ A + lam * jnp.eye(n), A.T @ b
        )
        err = float(
            jnp.linalg.norm(res.x - x_ref) / jnp.linalg.norm(x_ref)
        )
        assert err < 1e-8

    def test_warm_start_ridge_is_exact(self):
        """The augmented-operator warm start solves the TRUE ridge
        problem from any x0 — not the prox-regularized one around x0."""
        m, n, lam = 70, 40, 0.4
        A, b = _rect(m, n, seed=12)
        rng = np.random.default_rng(13)
        x0 = jnp.asarray(rng.standard_normal(n))
        res = lsmr(DenseMatrixOperator(A), b, x0=x0, damp=lam,
                   tol=1e-13, maxiter=400)
        x_ref = jnp.linalg.solve(A.T @ A + lam * jnp.eye(n), A.T @ b)
        err = float(
            jnp.linalg.norm(res.x - x_ref) / jnp.linalg.norm(x_ref)
        )
        assert err < 1e-8

    def test_zero_rhs_converges_immediately(self):
        A, _ = _rect(30, 20, seed=14)
        res = lsmr(DenseMatrixOperator(A), jnp.zeros(30), tol=1e-10,
                   maxiter=50)
        assert bool(res.info.converged)
        assert int(res.info.iterations) == 0
        assert int(res.info.status) == SolveStatus.CONVERGED

    def test_jit_matches_eager(self):
        A, b = _rect(60, 35, seed=15)
        r1 = lsmr(DenseMatrixOperator(A), b, damp=0.1, tol=1e-12,
                  maxiter=300)
        r2 = lsmr_jit(DenseMatrixOperator(A), b, damp=0.1, tol=1e-12,
                      maxiter=300)
        # XLA fusion reorders roundings: same trajectory to ~1 ulp of
        # the recurrence, identical stopping decision
        np.testing.assert_allclose(
            np.asarray(r1.x), np.asarray(r2.x), rtol=1e-11, atol=1e-13
        )
        assert int(r1.info.iterations) == int(r2.info.iterations)

    def test_pytree_rhs_and_domain(self):
        """LSMR crosses the flat engine through ravel/unravel pairs on
        BOTH sides — dict-structured b and x round-trip."""
        m, n = 40, 25
        A, b = _rect(m, n, seed=16)
        op_flat = DenseMatrixOperator(A)
        from repro.core import LinearOperator
        from repro.core import pytree as pt

        def mv(v):
            out = A @ jnp.concatenate([v["a"], v["b"]])
            return {"top": out[:25], "bot": out[25:]}

        def rmv(u):
            flat = A.T @ jnp.concatenate([u["top"], u["bot"]])
            return {"a": flat[:10], "b": flat[10:]}

        op = LinearOperator(matvec=mv, rmatvec=rmv)
        b_tree = {"top": b[:25], "bot": b[25:]}
        res = lsmr(op, b_tree, tol=1e-12, maxiter=300)
        flat = lsmr(op_flat, b, tol=1e-12, maxiter=300)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([res.x["a"], res.x["b"]])),
            np.asarray(flat.x),
            atol=1e-10,
        )

    def test_nonfinite_rhs_flagged(self):
        A, b = _rect(30, 20, seed=17)
        res = lsmr(DenseMatrixOperator(A), b.at[0].set(jnp.nan),
                   tol=1e-10, maxiter=50)
        assert not bool(res.info.converged)
        assert int(res.info.status) == SolveStatus.BREAKDOWN_NONFINITE
        assert int(res.info.iterations) == 0


# ---------------------------------------------------------------------------
# 2. fused lsmr_update kernel — impl-contract parity vs the ref oracle
# ---------------------------------------------------------------------------


class TestLSMRUpdateKernel:
    # four-impl contract: "pallas" (TPU), "interpret", "reference",
    # "chunked" — parity here runs interpret/chunked vs ref.lsmr_update.
    @pytest.mark.parametrize("impl", ["interpret", "chunked", "reference"])
    @pytest.mark.parametrize("n,block", [(4096, 4096), (1000, 1024), (130, 4096)])
    def test_lsmr_update_matches_oracle(self, impl, n, block):
        rng = np.random.default_rng(n)
        x, hbar, h, v = (
            jnp.asarray(rng.standard_normal(n), jnp.float32)
            for _ in range(4)
        )
        c0, c1, c2 = 0.37, -1.21, 0.83
        want = ref.lsmr_update(x, hbar, h, v, c0, c1, c2)
        got = ops.lsmr_update(
            x, hbar, h, v, c0, c1, c2, impl=impl, block=block
        )
        for g, w, name in zip(got, want, ("x", "hbar", "h")):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), atol=1e-6, err_msg=name
            )

    def test_lsmr_update_traced_coefficients(self):
        """The rotation coefficients are traced loop state — the op must
        accept tracers (no static leakage) under jit."""
        n = 512
        rng = np.random.default_rng(99)
        args = [
            jnp.asarray(rng.standard_normal(n), jnp.float32)
            for _ in range(4)
        ]

        @jax.jit
        def run(c0, c1, c2):
            return ops.lsmr_update(*args, c0, c1, c2, impl="chunked")

        got = run(0.2, 0.3, 0.4)
        want = ref.lsmr_update(*args, 0.2, 0.3, 0.4)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       atol=1e-6)


# ---------------------------------------------------------------------------
# 3. recycling wins on an ill-conditioned drifting sequence
# ---------------------------------------------------------------------------


class TestRecycledLSMR:
    def test_recycled_beats_cold_on_matvecs_and_iterations(self):
        mats, bs = _ill_conditioned_sequence(num=8)
        lam = 1e-4
        spec = dict(tol=1e-8, maxiter=400)

        cold_iters = cold_mv = 0
        for i in range(mats.shape[0]):
            r = lsmr(DenseMatrixOperator(mats[i]), bs[i], damp=lam,
                     **spec)
            assert bool(r.info.converged)
            cold_iters += int(r.info.iterations)
            cold_mv += int(r.info.matvecs)

        seq = solve_sequence_lsmr(
            mats, bs, k=8, ell=40, damp=lam,
            make_operator=DenseMatrixOperator,
            tol=1e-8, maxiter=400, refresh_aw="exact",
        )
        assert bool(np.all(np.asarray(seq.info.converged)))
        rec_iters = int(np.sum(np.asarray(seq.info.iterations)))
        rec_mv = int(np.sum(np.asarray(seq.info.matvecs)))

        # equal accuracy vs the dense ridge solution on the last system
        n = mats.shape[2]
        x_ref = jnp.linalg.solve(
            mats[-1].T @ mats[-1] + lam * jnp.eye(n),
            mats[-1].T @ bs[-1],
        )
        err = float(
            jnp.linalg.norm(seq.x[-1] - x_ref) / jnp.linalg.norm(x_ref)
        )
        assert err < 1e-5

        # the paper's claim, in the least-squares setting: fewer
        # iterations AND fewer total A/Aᵀ products, refresh included
        assert rec_iters < cold_iters
        assert rec_mv < cold_mv, (rec_mv, cold_mv)

    def test_sequence_jit_matches_eager(self):
        mats, bs = _ill_conditioned_sequence(num=3, m=45, n=30)
        kw = dict(k=4, ell=16, damp=1e-3,
                  make_operator=DenseMatrixOperator, tol=1e-8,
                  maxiter=200)
        eager = solve_sequence_lsmr(mats, bs, **kw)
        from repro.core import solve_sequence_lsmr_jit

        jitted = solve_sequence_lsmr_jit(mats, bs, **kw)
        np.testing.assert_array_equal(
            np.asarray(eager.x), np.asarray(jitted.x)
        )
        np.testing.assert_array_equal(
            np.asarray(eager.info.iterations),
            np.asarray(jitted.info.iterations),
        )


# ---------------------------------------------------------------------------
# 4. front-door dispatch — the method axis, zero new entry points
# ---------------------------------------------------------------------------


class TestFrontDoors:
    LAM = 0.2

    def _spec(self, method="deflsmr", **kw):
        base = dict(method=method, k=4, ell=12, tol=1e-10, maxiter=300,
                    lsq_shift=self.LAM)
        base.update(kw)
        return SolveSpec(**base)

    def test_solve_lsmr(self):
        A, b = _rect(60, 40, seed=41)
        res = solve(DenseMatrixOperator(A), b,
                    self._spec(method="lsmr"))
        x_ref = jnp.linalg.solve(
            A.T @ A + self.LAM * jnp.eye(40), A.T @ b
        )
        err = float(
            jnp.linalg.norm(res.x - x_ref) / jnp.linalg.norm(x_ref)
        )
        assert err < 1e-7

    def test_solve_deflsmr_state_roundtrip(self):
        A, b = _rect(60, 40, seed=42)
        spec = self._spec()
        cold = solve(DenseMatrixOperator(A), b, spec)
        assert cold.state.W.shape == (4, 40)
        assert int(cold.state.systems_solved) == 1
        warm = solve(DenseMatrixOperator(A), b, spec, cold.state)
        assert bool(warm.info.converged)
        assert int(warm.info.iterations) <= int(cold.info.iterations)

    def test_solve_sequence_deflsmr(self):
        mats, bs = _ill_conditioned_sequence(num=4, m=45, n=30)
        seq = solve_sequence(
            mats, bs, self._spec(lsq_shift=1e-3),
            make_operator=DenseMatrixOperator,
        )
        assert bool(np.all(np.asarray(seq.info.converged)))
        assert seq.state.W.shape == (4, 30)
        assert int(seq.state.systems_solved) == 4
        # second leg reuses the carried state
        seq2 = solve_sequence(
            mats, bs, self._spec(lsq_shift=1e-3), seq.state,
            make_operator=DenseMatrixOperator,
        )
        assert int(np.sum(np.asarray(seq2.info.iterations))) <= int(
            np.sum(np.asarray(seq.info.iterations))
        )

    def test_solve_batch_stateless_lsmr(self):
        mats, bs = _ill_conditioned_sequence(num=3, m=45, n=30)
        res = solve_batch(
            mats, bs, self._spec(method="lsmr", lsq_shift=1e-3),
            make_operator=DenseMatrixOperator,
        )
        assert bool(np.all(np.asarray(res.info.converged)))
        assert res.x.shape == (3, 30)

    def test_solve_pool_step_deflsmr_masked(self):
        mats, bs = _ill_conditioned_sequence(num=3, m=45, n=30)
        spec = self._spec(lsq_shift=1e-3)
        active = jnp.array([True, False, True])
        res = solve_pool_step(
            mats, bs, spec, None, active,
            make_operator=DenseMatrixOperator,
        )
        solved = np.asarray(res.state.systems_solved)
        assert solved.tolist() == [1, 0, 1]
        # inactive slot: zero rhs → converged before iteration 1
        assert int(np.asarray(res.info.iterations)[1]) == 0

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            SolveSpec(method="deflsmr", k=0)
        with pytest.raises(ValueError):
            SolveSpec(method="lsmr", lsq_shift=-1.0)
        with pytest.raises(ValueError):
            SolveSpec(method="cg", lsq_shift=0.5)
        with pytest.raises(ValueError):
            SolveSpec(method="lsmr", precond="jacobi")
        with pytest.raises(ValueError):
            SolveSpec(method="gmres")

    def test_lsq_methods_reject_preconditioner_argument(self):
        A, b = _rect(30, 20, seed=44)
        with pytest.raises(ValueError):
            solve(DenseMatrixOperator(A), b, self._spec(method="lsmr"),
                  M=lambda r: r)

    def test_state_passes_through_plain_lsmr(self):
        A, b = _rect(30, 20, seed=45)
        state = RecycleState.zeros(4, 20, b.dtype)
        res = solve(DenseMatrixOperator(A), b,
                    self._spec(method="lsmr"), state)
        for a, c in zip(
            jax.tree_util.tree_leaves(state),
            jax.tree_util.tree_leaves(res.state),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
